"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize, quantize_per_cluster
from repro.kernels import (flash_attention, flash_attention_ref, gleanvec_ip,
                           gleanvec_ip_ref, gleanvec_sq, gleanvec_sq_ref,
                           gleanvec_sq_sorted_ref, gleanvec_sq_topk,
                           gleanvec_sq_topk_ref, graph_scan_beam_step,
                           graph_scan_beam_step_ref, ip_topk, ip_topk_ref,
                           ivf_scan_topk, ivf_scan_topk_ref, kmeans_assign,
                           kmeans_assign_ref, sq_dot, sq_dot_ref)

RNG = np.random.default_rng(0)


def _randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def _sq_inputs(m, n, c, d):
    """Random per-cluster int8 database + query-side folded affine terms."""
    x_low = _randn(n, d)
    tags = jnp.asarray(RNG.integers(0, c, n).astype(np.int32))
    db = quantize_per_cluster(x_low, tags, c)
    q_views = _randn(m, c, d)
    q_scaled = q_views * db.delta[None]
    q_lo = jnp.einsum("mcd,cd->mc", q_views, db.lo)
    return q_scaled, q_lo, tags, db.codes


@pytest.mark.parametrize("m,n,d,k,tm,tn", [
    (8, 256, 32, 5, 8, 64),
    (20, 1000, 96, 10, 8, 128),     # non-divisible m/n -> padding
    (1, 513, 64, 16, 8, 256),
    (33, 4096, 160, 100, 16, 512),  # paper-scale d=160, k=100
])
def test_ip_topk_matches_ref(m, n, d, k, tm, tn):
    q, x = _randn(m, d), _randn(n, d)
    v, i = ip_topk(q, x, k, tm=tm, tn=tn, interpret=True)
    vr, ir = ip_topk_ref(q, x, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_ip_topk_dtypes(dtype):
    q, x = _randn(4, 32, dtype=dtype), _randn(128, 32, dtype=dtype)
    v, i = ip_topk(q, x, 5, tm=4, tn=64, interpret=True)
    vr, ir = ip_topk_ref(q, x, 5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-2,
                               atol=1e-2)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@pytest.mark.parametrize("m,n,c,d,tm,tn", [
    (3, 300, 8, 24, 2, 128),
    (5, 700, 16, 48, 4, 256),
    (1, 100, 48, 192, 1, 64),       # paper C=48, d=192 (t2i)
])
def test_gleanvec_ip_matches_ref(m, n, c, d, tm, tn):
    q_views = _randn(m, c, d)
    tags = jnp.asarray(RNG.integers(0, c, n).astype(np.int32))
    x_low = _randn(n, d)
    a = gleanvec_ip(q_views, tags, x_low, tm=tm, tn=tn, interpret=True)
    b = gleanvec_ip_ref(q_views, tags, x_low)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.tier1
@pytest.mark.parametrize("m,n,c,d,tm,tn", [
    (3, 300, 8, 24, 2, 128),
    (5, 1000, 16, 48, 4, 256),      # non-divisible m/n -> padding
    (1, 100, 48, 192, 1, 64),       # paper C=48, d=192 (t2i)
])
def test_gleanvec_sq_matches_ref(m, n, c, d, tm, tn):
    """Fused tag-select + int8 dot + per-cluster affine == jnp oracle."""
    q_scaled, q_lo, tags, codes = _sq_inputs(m, n, c, d)
    a = gleanvec_sq(q_scaled, q_lo, tags, codes, tm=tm, tn=tn,
                    interpret=True)
    b = gleanvec_sq_ref(q_scaled, q_lo, tags, codes)
    scale = float(jnp.abs(b).max())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2,
                               atol=1e-2 * scale)


@pytest.mark.tier1
@pytest.mark.parametrize("m,nb,c,d,lb,tn", [
    (4, 8, 6, 32, 128, 64),         # layout_block % tn == 0
    (3, 5, 8, 48, 64, 256),         # tn shrunk to the layout block
    (2, 6, 4, 16, 96, 256),         # neither divides -> gathered fallback
])
def test_gleanvec_sq_sorted_matches_ref(m, nb, c, d, lb, tn):
    """Single-tag-per-tile sorted path == expanded-tags oracle, including
    the tile-shrink and gathered fallbacks of the dispatcher."""
    n = nb * lb
    q_scaled, q_lo, _, codes = _sq_inputs(m, n, c, d)
    block_tags = jnp.asarray(RNG.integers(0, c, nb).astype(np.int32))
    a = gleanvec_sq(q_scaled, q_lo, block_tags, codes, layout_block=lb,
                    tm=2, tn=tn, interpret=True)
    b = gleanvec_sq_sorted_ref(q_scaled, q_lo, block_tags, codes, lb)
    scale = float(jnp.abs(b).max())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2,
                               atol=1e-2 * scale)


@pytest.mark.tier1
@pytest.mark.parametrize("m,n,c,d,k", [(4, 700, 8, 24, 10), (9, 300, 5, 16, 7)])
def test_gleanvec_sq_topk_matches_ref(m, n, c, d, k):
    """Fused blocked top-k (no dense (m, n)) == dense-then-top_k oracle."""
    q_scaled, q_lo, tags, codes = _sq_inputs(m, n, c, d)
    v1, i1 = gleanvec_sq_topk(q_scaled, q_lo, tags, codes, k, tm=4, tn=128,
                              interpret=True)
    v2, i2 = gleanvec_sq_topk_ref(q_scaled, q_lo, tags, codes, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.tier1
def test_gleanvec_sq_topk_sorted_emits_external_ids():
    """row_ids (the sort permutation) come straight out of the kernel and
    -1 padding rows can never win."""
    m, nb, c, d, lb, k = 3, 6, 4, 16, 128, 12
    n = nb * lb
    q_scaled, q_lo, _, codes = _sq_inputs(m, n, c, d)
    block_tags = jnp.asarray(RNG.integers(0, c, nb).astype(np.int32))
    perm = np.full(n, -1, np.int32)
    valid = RNG.permutation(n)[: n - 100]           # 100 padding rows
    perm[np.sort(valid)] = RNG.permutation(len(valid)).astype(np.int32)
    perm = jnp.asarray(perm)
    v1, i1 = gleanvec_sq_topk(q_scaled, q_lo, block_tags, codes, k,
                              row_ids=perm, layout_block=lb, tm=2, tn=64,
                              interpret=True)
    v2, i2 = gleanvec_sq_topk_ref(q_scaled, q_lo, block_tags, codes, k,
                                  row_ids=perm, layout_block=lb)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert (np.asarray(i1) >= 0).all()              # padding never wins


def _scan_inputs(m, nb, c, d, lb, s, n_pad=0, f32=False, seed=1):
    """Random sorted-layout inputs + a -1-padded per-query block schedule
    (possibly with unscheduled blocks -- the kernel must never read them)."""
    rng = np.random.default_rng(seed)
    n = nb * lb
    q_scaled, q_lo, _, codes = _sq_inputs(m, n, c, d)
    if f32:
        codes = _randn(n, d)
    block_tags = jnp.asarray(rng.integers(0, c, nb).astype(np.int32))
    perm = np.arange(n, dtype=np.int32)
    if n_pad:
        perm[rng.permutation(n)[:n_pad]] = -1        # dead/padding rows
    sched = rng.integers(-1, nb, (m, s)).astype(np.int32)
    return (q_scaled, q_lo, block_tags, jnp.asarray(perm), codes,
            jnp.asarray(sched))


@pytest.mark.tier1
@pytest.mark.parametrize("m,nb,c,d,lb,s,tn", [
    (4, 8, 6, 32, 128, 3, 64),      # layout_block % tn == 0
    (3, 5, 8, 48, 64, 5, 256),      # tn > layout_block -> tile shrink
    (1, 6, 4, 16, 96, 2, 64),       # tn does not divide -> tile shrink
])
def test_ivf_scan_topk_matches_ref(m, nb, c, d, lb, s, tn):
    """Scalar-prefetch range-scan kernel == gather oracle: schedule-driven
    slab streaming, -1 schedule pads and -1 row_ids never win."""
    qs, ql, bt, rid, codes, sched = _scan_inputs(m, nb, c, d, lb, s,
                                                 n_pad=40)
    v1, i1 = ivf_scan_topk(qs, ql, bt, rid, codes, sched, 7,
                           layout_block=lb, tn=tn, interpret=True)
    v2, i2 = ivf_scan_topk_ref(qs, ql, bt, rid, codes, sched, 7,
                               layout_block=lb)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.tier1
def test_ivf_scan_topk_f32_rows_and_empty_schedule():
    """The unquantized sorted scorer's f32 rows ride the same kernel, and
    an all-padding schedule row returns (-inf, -1) everywhere."""
    qs, ql, bt, rid, codes, sched = _scan_inputs(2, 6, 4, 24, 64, 4,
                                                 f32=True)
    sched = sched.at[1].set(-1)                      # query 1: no blocks
    v1, i1 = ivf_scan_topk(qs, ql, bt, rid, codes, sched, 5,
                           layout_block=64, tn=64, interpret=True)
    v2, i2 = ivf_scan_topk_ref(qs, ql, bt, rid, codes, sched, 5,
                               layout_block=64)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert (np.asarray(i1)[1] == -1).all()
    assert (np.asarray(v1)[1] < -1e37).all()


def _graph_scan_inputs(m, nb, c, d, lb, s, b, n_pad=0, f32=False, seed=3):
    """Random sorted-layout inputs + per-query neighbor sorted-row lists
    (with -1 pads and repeats) + a random incoming beam (distinct ids,
    some empty slots)."""
    rng = np.random.default_rng(seed)
    n = nb * lb
    q_scaled, q_lo, _, codes = _sq_inputs(m, n, c, d)
    if f32:
        codes = _randn(n, d)
    block_tags = jnp.asarray(rng.integers(0, c, nb).astype(np.int32))
    perm = rng.permutation(n).astype(np.int32)
    if n_pad:
        perm[rng.permutation(n)[:n_pad]] = -1        # dead/padding rows
    nbr = rng.integers(-1, n, (m, s)).astype(np.int32)
    nbr[0, 1:] = nbr[0, 0]                           # repeated rows
    bvals = 50.0 * rng.standard_normal((m, b)).astype(np.float32)
    bids = np.stack([rng.choice(n, b, replace=False)
                     for _ in range(m)]).astype(np.int32)
    empty = rng.random((m, b)) < 0.25                # unfilled beam slots
    bvals[empty] = np.float32(-3.4e38)
    bids[empty] = -1
    return (q_scaled, q_lo, block_tags, jnp.asarray(perm), codes,
            jnp.asarray(nbr), jnp.asarray(bvals), jnp.asarray(bids))


def _assert_same_beam(kv, ki, rv, ri):
    """Kernel beams are slot-ordered, the oracle's are score-sorted --
    compare as (id -> value) maps: beam ids are distinct (-1 empties all
    ride the -inf sentinel), so sorting by id aligns the multisets."""
    kv, ki = np.asarray(kv), np.asarray(ki)
    rv, ri = np.asarray(rv), np.asarray(ri)
    ko, ro = np.argsort(ki, axis=1), np.argsort(ri, axis=1)
    np.testing.assert_array_equal(np.take_along_axis(ki, ko, 1),
                                  np.take_along_axis(ri, ro, 1))
    np.testing.assert_allclose(np.take_along_axis(kv, ko, 1),
                               np.take_along_axis(rv, ro, 1),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.tier1
@pytest.mark.parametrize("m,nb,c,d,lb,s,b,tn", [
    (4, 8, 6, 32, 128, 40, 12, 8),   # layout_block % tn == 0
    (3, 5, 8, 48, 64, 24, 8, 48),    # tn does not divide -> tile shrink
    (1, 6, 4, 16, 96, 10, 6, 128),   # tn > layout_block -> tile shrink
])
def test_graph_scan_beam_step_matches_ref(m, nb, c, d, lb, s, b, tn):
    """Fused beam-step kernel == gather/top_k oracle: slab streaming from
    the neighbor-row schedule, repeated rows score once, dead rows and
    in-beam candidates never enter, beam multiset identical."""
    qs, ql, bt, rid, codes, nbr, bv, bi = _graph_scan_inputs(
        m, nb, c, d, lb, s, b, n_pad=30)
    kv, ki = graph_scan_beam_step(qs, ql, bt, rid, codes, nbr, bv, bi,
                                  layout_block=lb, tn=tn, interpret=True)
    rv, ri = graph_scan_beam_step_ref(qs, ql, bt, rid, codes, nbr, bv, bi,
                                      layout_block=lb)
    _assert_same_beam(kv, ki, rv, ri)


@pytest.mark.tier1
def test_graph_scan_f32_rows_and_empty_expansion():
    """The unquantized sorted scorer's f32 rows ride the same kernel, and
    an all-padding neighbor row leaves that query's beam untouched."""
    qs, ql, bt, rid, codes, nbr, bv, bi = _graph_scan_inputs(
        3, 6, 4, 24, 64, 16, 8, f32=True)
    nbr = nbr.at[1].set(-1)                          # query 1: no neighbors
    kv, ki = graph_scan_beam_step(qs, ql, bt, rid, codes, nbr, bv, bi,
                                  layout_block=64, tn=8, interpret=True)
    rv, ri = graph_scan_beam_step_ref(qs, ql, bt, rid, codes, nbr, bv, bi,
                                      layout_block=64)
    _assert_same_beam(kv, ki, rv, ri)
    np.testing.assert_array_equal(np.asarray(ki)[1], np.asarray(bi)[1])
    np.testing.assert_allclose(np.asarray(kv)[1], np.asarray(bv)[1])


@pytest.mark.parametrize("n,c,d,tn", [
    (500, 13, 64, 128), (2048, 48, 200, 512), (100, 4, 16, 64)])
def test_kmeans_assign_matches_ref(n, c, d, tn):
    x, cent = _randn(n, d), _randn(c, d)
    t1, s1 = kmeans_assign(x, cent, tn=tn, interpret=True)
    t2, s2 = kmeans_assign_ref(x, cent)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("m,n,d,tm,tn", [
    (4, 300, 48, 4, 128), (9, 1000, 160, 8, 256)])
def test_sq_dot_matches_ref(m, n, d, tm, tn):
    x = _randn(n, d)
    db = quantize(x)
    q = _randn(m, d)
    s1 = sq_dot(q, db.codes, db.lo, db.delta, tm=tm, tn=tn,
                interpret=True)
    s2 = sq_dot_ref(q, db.codes, db.lo, db.delta)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("b,h,kv,s,dh,bq,bk,window", [
    (1, 4, 4, 64, 16, 32, 32, None),     # MHA
    (2, 4, 2, 96, 32, 32, 32, None),     # GQA
    (2, 8, 2, 128, 16, 64, 32, None),    # GQA group 4
    (1, 4, 2, 128, 32, 32, 32, 48),      # sliding window
    (2, 4, 2, 80, 32, 32, 32, None),     # padded seq
])
def test_flash_attention_matches_ref(b, h, kv, s, dh, bq, bk, window):
    q = _randn(b, h, s, dh)
    k = _randn(b, kv, s, dh)
    v = _randn(b, kv, s, dh)
    o1 = flash_attention(q, k, v, causal=True, window=window, bq=bq, bk=bk,
                         interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)


def test_flash_attention_bf16():
    q = _randn(1, 2, 64, 32).astype(jnp.bfloat16)
    k = _randn(1, 2, 64, 32).astype(jnp.bfloat16)
    v = _randn(1, 2, 64, 32).astype(jnp.bfloat16)
    o1 = flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    o2 = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=3e-2,
                               atol=3e-2)
