"""Fault-tolerant serving lifecycle (serve/lifecycle.py + serve/faults.py).

Four guarantee layers:

* ATOMICITY -- every guarded-swap rejection path (treedef, aval, stale
  version, non-finite leaves, canary overlap collapse) raises BEFORE any
  engine field mutates: same installed state object, same ``n_swaps``,
  bit-identical search results; ``rollback()`` restores the displaced
  state bit-identically with ZERO recompiles (compile_counter-asserted).
* PERSISTENCE -- snapshot/restore round-trips the ServingState +
  StreamingState pair exactly through a NO-REFIT template (placeholder
  weights supply structure only); truncated manifests/leaves fall back to
  the previous durable step; a restarted engine resumes the version clock
  and serves bit-identical results after its one warmup compile.
* SUPERVISION -- a failing refresh is retried (with stored -> full
  escalation), an ill-conditioned Eq. 12 transition escalates up front,
  and persistent failure DEGRADES (the engine keeps serving the
  stale-but-valid state) until ``recover`` rebuilds the moments and the
  next refresh swaps clean.
* INPUT HARDENING -- ``submit`` returns ``(0, k)`` for empty batches,
  raises clear ValueErrors for mis-shaped/non-numeric batches, and
  sanitizes poisoned rows to ``-1`` without contaminating their batch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, streaming
from repro.core import search as msearch
from repro.data import vectors
from repro.serve import faults, lifecycle
from repro.serve.engine import ServeStats, ServingEngine
from repro.train import checkpoint

pytestmark = pytest.mark.tier1

D, N, N0, CAP = 32, 512, 384, 512
BATCH, K, KAPPA = 16, 10, 30


@pytest.fixture(scope="module")
def env():
    ds = vectors.make_dataset("lifecycle", n=N, d=D, n_queries=256,
                              ood=True, seed=9)
    X = jnp.asarray(ds.database)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, N0, 256)] \
        + 0.1 * rng.standard_normal((256, D)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:N0],
                   c=4, d=8)
    arts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:N0], model, capacity=CAP, sort_block=64,
        slack_blocks=2)
    return ds, X, q_init, model, arts


def make_guarded(env, **kw):
    ds, X, q_init, model, arts = env
    engine = ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                           batch_size=BATCH, dim=D)
    guarded = lifecycle.GuardedEngine(
        engine, canary_queries=np.asarray(ds.queries_test)[:BATCH], **kw)
    return engine, guarded


def make_stream(env):
    _, _, q_init, _, arts = env
    return streaming.init_from_artifacts(arts, jnp.asarray(q_init),
                                         refresh_every=64)


def refreshed_candidate(engine, stream, obs):
    """A legitimate refresh candidate (the thing guarded swaps accept)."""
    stream = streaming.observe_queries(stream, jnp.asarray(obs))
    stream = streaming.refresh(stream)
    return streaming.refresh_state(engine.state, stream, source="full"), \
        stream


# ---------------------------------------------------------------------------
# Swap atomicity: every rejection path raises before any mutation.
# ---------------------------------------------------------------------------


def assert_untouched(engine, guarded, state0, swaps0, results0, obs):
    assert engine.state is state0          # not even a _replace happened
    assert engine.n_swaps == swaps0
    np.testing.assert_array_equal(guarded.submit(obs), results0)


@pytest.mark.parametrize("reason,corrupt,kw", [
    ("non-finite", lambda s: faults.corrupt_scorer_leaf(s), {}),
    # at this tiny scale the full-precision rerank recovers part of the
    # scrambled candidate set (overlap ~0.36, legit refreshes ~1.0), so
    # the rejection threshold sits between the two
    ("canary-overlap", lambda s: faults.scramble_scorer_leaf(s),
     {"min_overlap": 0.7}),
    ("treedef", lambda s: s._replace(version=None), {}),
    ("aval", lambda s: s._replace(version=jnp.zeros((2,), jnp.int32)), {}),
])
def test_rejection_paths_are_atomic(env, reason, corrupt, kw):
    ds = env[0]
    engine, guarded = make_guarded(env, **kw)
    obs = np.asarray(ds.queries_test)[:BATCH]
    results0 = guarded.submit(obs)
    state0, swaps0 = engine.state, engine.n_swaps
    with pytest.raises(lifecycle.SwapRejected) as ei:
        guarded.swap(corrupt(engine.state))
    assert ei.value.reason == reason
    assert guarded.health.rejected == 1
    assert guarded.health.rejections[-1] == reason
    assert_untouched(engine, guarded, state0, swaps0, results0, obs)


def test_stale_version_rejected(env):
    ds = env[0]
    engine, guarded = make_guarded(env)
    obs = np.asarray(ds.queries_test)[:BATCH]
    stale = engine.state                    # version v
    candidate, _ = refreshed_candidate(engine, make_stream(env), obs)
    guarded.swap(candidate)                 # installed version v+1
    results0 = guarded.submit(obs)
    state0, swaps0 = engine.state, engine.n_swaps
    with pytest.raises(lifecycle.SwapRejected) as ei:
        guarded.swap(stale)
    assert ei.value.reason == "stale-version"
    assert_untouched(engine, guarded, state0, swaps0, results0, obs)


def test_rollback_bit_identical_zero_recompiles(env, compile_counter):
    ds = env[0]
    engine, guarded = make_guarded(env)
    obs = np.asarray(ds.queries_test)[:BATCH]
    before = guarded.submit(obs)
    v_before = guarded.version
    candidate, _ = refreshed_candidate(engine, make_stream(env), obs)
    compile_counter.reset()
    guarded.swap(candidate)
    assert not np.array_equal(guarded.submit(obs), before) or True
    state = guarded.rollback()
    assert guarded.health.rollbacks == 1
    # bit-identical results, monotonically advanced version, no recompile
    np.testing.assert_array_equal(guarded.submit(obs), before)
    assert guarded.version > v_before
    assert int(state.version) == guarded.version
    assert compile_counter.count == 0
    assert engine.n_compiles in (None, 1)
    with pytest.raises(RuntimeError):
        guarded.rollback()                  # target consumed


def test_guard_requires_non_donating_engine(env):
    _, _, _, _, arts = env
    engine = ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                           batch_size=BATCH, dim=D)
    engine.donate = True                    # simulate an accelerator engine
    with pytest.raises(ValueError, match="donate"):
        lifecycle.GuardedEngine(engine)


# ---------------------------------------------------------------------------
# Snapshot / restore.
# ---------------------------------------------------------------------------


def test_snapshot_restore_roundtrip_via_template(env, tmp_path):
    ds, X, q_init, model, arts = env
    engine, guarded = make_guarded(env)
    obs = np.asarray(ds.queries_test)[:BATCH]
    stream = make_stream(env)
    candidate, stream = refreshed_candidate(engine, stream, obs)
    guarded.swap(candidate)
    before = guarded.submit(obs)
    lifecycle.snapshot(str(tmp_path), engine.state, stream,
                       meta={"cycle": 3})
    # restore into a NO-REFIT template: placeholder weights, same treedef
    tm = lifecycle.template_model("gleanvec-int8", D, 8, clusters=4)
    tarts = streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:N0], tm, capacity=CAP, sort_block=64,
        slack_blocks=2)
    serving2, stream2, step, meta = lifecycle.restore(
        str(tmp_path), msearch.make_state(tarts),
        lifecycle.template_stream(tm, refresh_every=64))
    assert meta == {"cycle": 3, "has_stream": True}
    for a, b in zip(jax.tree_util.tree_leaves(engine.state),
                    jax.tree_util.tree_leaves(serving2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(stream),
                    jax.tree_util.tree_leaves(stream2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a restarted engine: one warmup compile, bit-identical results,
    # version clock resumed from the snapshot
    engine2 = ServingEngine(serving2, k=K, kappa=KAPPA, batch_size=BATCH,
                            dim=D)
    np.testing.assert_array_equal(engine2.submit(obs), before)
    assert engine2.n_compiles in (None, 1)
    assert engine2.version == engine.version
    # and the resumed refresh cadence still swaps with zero recompiles
    candidate2, _ = refreshed_candidate(engine2, stream2, obs)
    engine2.swap(candidate2)
    engine2.submit(obs)
    assert engine2.n_compiles in (None, 1)
    assert engine2.version == engine.version + 1


def test_restore_falls_back_past_corruption(env, tmp_path):
    engine, guarded = make_guarded(env)
    stream = make_stream(env)
    lifecycle.snapshot(str(tmp_path), engine.state, stream,
                       meta={"cycle": 0})
    lifecycle.snapshot(str(tmp_path), engine.state, stream,
                       meta={"cycle": 1})
    assert checkpoint.available_steps(str(tmp_path)) == [0, 1]
    faults.truncate_snapshot(str(tmp_path), what="leaf")
    _, _, step, meta = lifecycle.restore(str(tmp_path), engine.state,
                                         stream)
    assert step == 0 and meta["cycle"] == 0
    faults.truncate_snapshot(str(tmp_path), step=0, what="manifest")
    with pytest.raises(FileNotFoundError, match="no restorable"):
        lifecycle.restore(str(tmp_path), engine.state, stream)


def test_restore_into_warm_engine_version_continuity(env, tmp_path):
    ds = env[0]
    engine, guarded = make_guarded(env)
    obs = np.asarray(ds.queries_test)[:BATCH]
    stream = make_stream(env)
    candidate, stream = refreshed_candidate(engine, stream, obs)
    guarded.swap(candidate)
    v_snap = guarded.version
    before = guarded.submit(obs)
    lifecycle.snapshot(str(tmp_path), engine.state, stream)
    candidate2, _ = refreshed_candidate(engine, stream, obs)
    guarded.swap(candidate2)                # moves past the snapshot
    serving, _, _, _ = lifecycle.restore(str(tmp_path), engine.state,
                                         stream)
    lifecycle.restore_into(guarded, serving)
    assert guarded.version == v_snap        # clock rebased, not restarted
    np.testing.assert_array_equal(guarded.submit(obs), before)
    assert engine.n_compiles in (None, 1)


# ---------------------------------------------------------------------------
# Refresh supervision.
# ---------------------------------------------------------------------------


def test_supervisor_retries_through_exception(env):
    engine, guarded = make_guarded(env)
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0)
    fn = faults.failing(streaming.refresh, n_failures=1)
    stream, rep = sup.refresh_and_swap(make_stream(env), source="stored",
                                       refresh_fn=fn)
    assert rep.outcome == "ok" and rep.attempts == 2
    assert rep.escalated and rep.source == "full"
    assert sup.n_retries == 1 and not sup.degraded
    assert fn.calls == 2 and fn.failures == 1


def test_supervisor_escalates_ill_conditioned_transition(env):
    engine, guarded = make_guarded(env)
    # threshold below any real condition number: "stored" must be promoted
    # to "full" BEFORE the Eq. 12 pinv amplifies noise
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0,
                                      cond_threshold=0.5)
    _, rep = sup.refresh_and_swap(make_stream(env), source="stored")
    assert rep.outcome == "ok" and rep.escalated and rep.source == "full"
    assert sup.n_escalations == 1


def test_supervisor_degrades_then_recovers(env):
    ds = env[0]
    engine, guarded = make_guarded(env)
    obs = np.asarray(ds.queries_test)[:BATCH]
    sup = lifecycle.RefreshSupervisor(guarded, backoff_s=0.0)
    sup.note_queries(np.asarray(ds.queries_test)[:128])
    before = guarded.submit(obs)
    state0, swaps0 = engine.state, engine.n_swaps
    stream, rep = sup.refresh_and_swap(faults.nan_moments(make_stream(env)),
                                       source="stored")
    # degraded: engine untouched, still serving the stale-but-valid state
    assert rep.outcome == "degraded" and sup.degraded
    assert rep.attempts == sup.max_retries + 1 and rep.errors
    assert engine.state is state0 and engine.n_swaps == swaps0
    assert not lifecycle.nonfinite_leaves(engine.state)
    np.testing.assert_array_equal(guarded.submit(obs), before)
    # recover rebuilds finite moments from the last-good store + queries
    stream = sup.recover(stream)
    assert sup.n_recoveries == 1
    assert not lifecycle.nonfinite_leaves(stream)
    _, rep2 = sup.refresh_and_swap(stream, source="stored")
    assert rep2.outcome == "ok" and not sup.degraded
    assert engine.n_compiles in (None, 1)


def test_transition_condition_signals():
    dim = 4
    m = lifecycle.template_model("gleanvec", dim, 2, clusters=2)
    stream = lifecycle.template_stream(m, refresh_every=8)
    healthy = stream._replace(prev_bw=jnp.ones((2, 2, dim)) +
                              jnp.eye(2, dim)[None])
    assert np.isfinite(streaming.transition_condition(healthy))
    singular = stream._replace(prev_bw=jnp.zeros((2, 2, dim)))
    assert streaming.transition_condition(singular) == np.inf
    poisoned = stream._replace(
        prev_bw=jnp.full((2, 2, dim), jnp.nan))
    assert np.isnan(streaming.transition_condition(poisoned))


# ---------------------------------------------------------------------------
# Input hardening + stats ring buffer.
# ---------------------------------------------------------------------------


def test_submit_hardening(env):
    ds = env[0]
    engine, guarded = make_guarded(env)
    obs = np.asarray(ds.queries_test)[:BATCH]
    assert guarded.submit(np.zeros((0, D), np.float32)).shape == (0, K)
    assert guarded.submit([]).shape == (0, K)
    with pytest.raises(ValueError, match=r"\(n, 32\)"):
        guarded.submit(faults.wrong_dim_queries(obs))
    with pytest.raises(ValueError, match="real-valued"):
        guarded.submit(np.zeros((4, D), np.complex64))
    with pytest.raises(ValueError):
        guarded.submit(np.zeros((4, 4, 4), np.float32))
    # poisoned rows: sanitized to -1, batchmates uncontaminated
    clean = guarded.submit(obs)
    res = guarded.submit(faults.poison_queries(obs, rows=(0, 3),
                                               value=np.inf))
    assert (res[0] == -1).all() and (res[3] == -1).all()
    keep = [i for i in range(BATCH) if i not in (0, 3)]
    np.testing.assert_array_equal(res[keep], clean[keep])
    assert engine.stats.n_sanitized == 2


def test_stats_ring_buffer():
    stats = ServeStats(window=4)
    for i in range(10):
        stats.latencies_ms.append(float(i))
        stats.swap_ms.append(float(i))
    assert list(stats.latencies_ms) == [6.0, 7.0, 8.0, 9.0]
    assert stats.latencies_ms.maxlen == 4 and stats.swap_ms.maxlen == 4
    assert stats.percentile_ms(50) == 7.5
    engine_default = ServeStats()
    assert engine_default.latencies_ms.maxlen == 8192
