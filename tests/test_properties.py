"""Hypothesis property-based tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import assume, given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import linalg, metrics, quantization
from repro.index import topk

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=4, max_side=24),
                  elements=st.floats(-10, 10, width=32)))
def test_sphering_identity(x):
    """W @ W_pinv acts as identity on the row space of K (PSD)."""
    assume(float(np.abs(x).max()) > 1e-2)  # eigh is flaky on ~zero matrices
    k = np.asarray(jnp.asarray(x) @ jnp.asarray(x).T)
    w, w_pinv = linalg.sphering_from_moment(jnp.asarray(k))
    w, w_pinv = np.asarray(w), np.asarray(w_pinv)
    scale = max(float(np.abs(k).max()), 1.0)
    # W^2 == K (norm-relative; hypothesis explores degenerate spectra)
    assert np.abs(w @ w - k).max() / scale < 5e-3
    proj = w @ w_pinv
    # projector: idempotent and symmetric
    assert np.abs(proj @ proj - proj).max() < 5e-2
    assert np.abs(proj - proj.T).max() < 2e-2


@settings(**SETTINGS)
@given(st.integers(2, 16), st.integers(2, 10))
def test_topk_eigvecs_orthonormal(d_full, d):
    d = min(d, d_full)
    rng = np.random.default_rng(d_full * 31 + d)
    a = rng.standard_normal((d_full, d_full)).astype(np.float32)
    m = jnp.asarray(a @ a.T)
    p = linalg.topk_eigvecs(m, d)
    np.testing.assert_allclose(np.asarray(p @ p.T), np.eye(d), atol=1e-4)


@settings(**SETTINGS)
@given(hnp.arrays(np.float32, (3, 12),
                  elements=st.floats(-5, 5, width=32)),
       hnp.arrays(np.float32, (3, 12),
                  elements=st.floats(-5, 5, width=32)))
def test_merge_topk_equals_concat_topk(va, vb):
    """merge_topk(a, b) == top_k(concat(a, b)) by values."""
    ia = jnp.arange(12)[None].repeat(3, 0)
    ib = jnp.arange(12, 24)[None].repeat(3, 0)
    v, _ = topk.merge_topk(jnp.asarray(va), ia, jnp.asarray(vb), ib, 5)
    ref = jax.lax.top_k(jnp.concatenate([jnp.asarray(va),
                                         jnp.asarray(vb)], 1), 5)[0]
    np.testing.assert_allclose(np.asarray(v), np.asarray(ref), rtol=1e-6)


@settings(**SETTINGS)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=2, max_side=32),
                  elements=st.floats(-100, 100, width=32)))
def test_quantization_error_bound(x):
    """|dequant(quant(x)) - x| <= delta / 2 elementwise (round-to-nearest)."""
    db = quantization.quantize(jnp.asarray(x))
    err = np.abs(np.asarray(quantization.dequantize(db)) - x)
    bound = np.asarray(db.delta) * 0.5 + 1e-5
    assert (err <= bound).all()


@settings(**SETTINGS)
@given(st.integers(1, 8), st.integers(1, 10))
def test_recall_bounds(nq, k):
    rng = np.random.default_rng(nq * 131 + k)
    retrieved = jnp.asarray(rng.integers(0, 50, (nq, k)))
    r_self = metrics.recall_at_k(retrieved, retrieved)
    assert float(r_self) == 1.0
    disjoint = retrieved + 100
    assert float(metrics.recall_at_k(retrieved, disjoint)) == 0.0


@settings(**SETTINGS)
@given(st.integers(2, 6))
def test_fm_sum_square_identity(n_fields):
    """FM pairwise identity: sum_{i<j} <v_i, v_j> ==
    0.5 (||sum v||^2 - sum ||v||^2)."""
    rng = np.random.default_rng(n_fields)
    v = rng.standard_normal((n_fields, 8)).astype(np.float32)
    brute = sum(float(v[i] @ v[j]) for i in range(n_fields)
                for j in range(i + 1, n_fields))
    s = v.sum(0)
    trick = 0.5 * (float(s @ s) - float((v * v).sum()))
    np.testing.assert_allclose(brute, trick, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 1000), st.integers(1, 64))
def test_rope_preserves_norm(pos, dh2):
    """Rotary embedding is a rotation: preserves vector norms."""
    from repro.models.layers import rope
    dh = 2 * dh2
    rng = np.random.default_rng(pos * 7 + dh)
    x = jnp.asarray(rng.standard_normal((1, 1, 1, dh)).astype(np.float32))
    y = rope(x, jnp.asarray([[pos]]))
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(1, 30), st.integers(1, 5))
def test_embedding_bag_mean(n_items, bags):
    """EmbeddingBag(take+segment_sum) == per-bag numpy mean."""
    from repro.models.embedding import embedding_bag
    rng = np.random.default_rng(n_items * 13 + bags)
    table = jnp.asarray(rng.standard_normal((50, 4)).astype(np.float32))
    idx = rng.integers(0, 50, n_items)
    seg = np.sort(rng.integers(0, bags, n_items))
    out = embedding_bag(table, jnp.asarray(idx), jnp.asarray(seg), bags,
                        combiner="mean")
    for b in range(bags):
        rows = idx[seg == b]
        expect = (np.asarray(table)[rows].mean(0) if len(rows)
                  else np.zeros(4))
        np.testing.assert_allclose(np.asarray(out[b]), expect, rtol=1e-5,
                                   atol=1e-6)
