"""Host-tier rerank: the two-level memory hierarchy
(``core/rerank_tier.py`` + the pipelined serving path).

Four guarantee layers:

* PARITY -- demoting ``x_full`` to the host tier changes WHERE the
  full-precision rows live, never WHAT the search returns: the two-stage
  pipeline (compiled ``state_candidates`` -> host kappa-row gather ->
  compiled ``rerank_candidates``) returns ids identical to the one-shot
  ``state_search``, for every scorer family x {flat, reduced-probe IVF,
  fused graph, mesh-free sharded spill}, on ID and OOD queries.
* SERVING -- the double-buffered ``ServingEngine.submit`` pipeline serves
  identical results to the all-HBM engine, moves EXACTLY
  batches*batch*kappa*D*4 bytes host->device (``host_bytes`` ==
  ``host_bytes_lb``), and swaps streamed refreshes with ZERO recompiles
  (the leafless-aux store keeps the state treedef stable); GuardedEngine
  guards and snapshot/restore round-trip the tier without promoting it.
* EDGE CASES -- an all-(-1) candidate row reranks to all -1 on both
  tiers; kappa > n and k > n pad with -1 identically on both tiers (flat
  and graph traversals).
* TRACE SAFETY -- ``rerank`` over a host store refuses to run inside jit
  (the gather is host-driven) with an actionable error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, \
    rerank_tier, streaming
from repro.core import scorer as sc
from repro.core import search as msearch
from repro.data import vectors
from repro.index import distributed, graph, ivf
from repro.index.protocol import replace
from repro.serve import faults, lifecycle
from repro.serve.engine import ServingEngine

pytestmark = pytest.mark.tier1

ALL_MODES = ["full", "sphering", "gleanvec", "sphering-int8",
             "gleanvec-int8", "gleanvec-sorted", "gleanvec-int8-sorted"]

K, KAPPA = 10, 30


@pytest.fixture(scope="module")
def setup():
    ds = vectors.make_dataset("rerank-tier", n=2048, d=64, n_queries=64,
                              ood=True, seed=7)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    lin = lvs.fit(Q, X, 24)
    gvm = gv.fit(jax.random.PRNGKey(0), Q, X, c=8, d=24)
    return ds, X, lin, gvm


def _model_for(mode, lin, gvm):
    if mode == "full":
        return None
    return lin if mode.startswith("sphering") else gvm


def _host_search(arts_host, q, k, kappa, index=None, block=256):
    """The two-stage pipeline as a plain function: compiled candidates,
    host gather + compiled rerank outside the trace."""
    state = msearch.make_state(arts_host, index=index, block=block)
    cand = jax.jit(msearch.state_candidates,
                   static_argnames=("kappa",))(q, state, kappa=kappa)
    return msearch.rerank(q, arts_host, np.asarray(cand), k)


# ---------------------------------------------------------------------------
# PARITY: host tier == HBM, every scorer family x traversal x regime.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ALL_MODES)
@pytest.mark.parametrize("regime", ["id", "ood"])
def test_host_matches_hbm_flat(setup, mode, regime):
    ds, X, lin, gvm = setup
    q = jnp.asarray(ds.queries_test if regime == "ood"
                    else ds.database[:48])
    arts = msearch.build_artifacts(mode, X, _model_for(mode, lin, gvm))
    ref = msearch.state_search(q, msearch.make_state(arts, block=256),
                               K, KAPPA)
    arts_host = msearch.demote_rerank_tier(arts)
    assert msearch.host_tier(arts_host) is not None
    got = _host_search(arts_host, q, K, KAPPA)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                  err_msg=f"{mode}/{regime}")
    # promote is the exact inverse
    back = msearch.promote_rerank_tier(arts_host)
    assert msearch.host_tier(back) is None
    np.testing.assert_array_equal(np.asarray(back.x_full),
                                  np.asarray(arts.x_full))


def test_host_matches_hbm_ivf_reduced_probe(setup):
    """The candidates stage is traversal-agnostic: reduced-space coarse
    probing composes with the host tier unchanged."""
    ds, X, lin, gvm = setup
    q = jnp.asarray(ds.queries_test)
    arts = msearch.build_artifacts("gleanvec-int8", X, gvm)
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=16, nprobe=8)
    iv = ivf.with_reduced_centers(iv, arts.scorer, gvm)
    ref = msearch.state_search(q, msearch.make_state(arts, index=iv),
                               K, KAPPA)
    got = _host_search(msearch.demote_rerank_tier(arts), q, K, KAPPA,
                       index=iv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("mode", ["gleanvec-sorted", "gleanvec-int8-sorted"])
def test_host_matches_hbm_fused_graph(setup, mode):
    """The gather-free fused beam step emits -1-padded original-id
    candidates; the host rerank consumes them identically to HBM."""
    ds, X, lin, gvm = setup
    q = jnp.asarray(ds.queries_test)
    arts = msearch.build_artifacts(mode, X, gvm)
    g = graph.build(ds.database, r=12, n_iters=3, seed=0)
    g = graph.with_fused_scan(replace(g, beam=32, max_hops=48), arts.scorer)
    assert g.fused
    ref = msearch.state_search(q, msearch.make_state(arts, index=g),
                               K, KAPPA)
    got = _host_search(msearch.demote_rerank_tier(arts), q, K, KAPPA,
                       index=g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref), mode)


@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_sharded_spill_matches_hbm(setup, kind):
    """``build_sharded_artifacts(spill_host=True)``: the sharded stack's
    global-id candidates route through per-shard host buffers and return
    ids identical to the all-HBM sharded search."""
    ds, X, lin, gvm = setup
    q = jnp.asarray(ds.queries_test)
    kwargs = dict(n_shards=4, key=jax.random.PRNGKey(1), n_lists=16,
                  nprobe=8)
    sh, arts = distributed.build_sharded_artifacts(
        kind, "gleanvec", X, gvm, spill_host=False, **kwargs)
    sh2, arts_host = distributed.build_sharded_artifacts(
        kind, "gleanvec", X, gvm, spill_host=True, **kwargs)
    store = msearch.host_tier(arts_host)
    assert isinstance(store, rerank_tier.ShardedHostStore)
    assert len(store.shards) == 4
    ref = msearch.state_search(q, msearch.make_state(arts, index=sh),
                               K, KAPPA)
    got = _host_search(arts_host, q, K, KAPPA, index=sh2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref), kind)


def test_sharded_host_store_routes_global_ids(setup):
    """The store itself: global-id gathers cross shard boundaries, -1
    clamps to row 0 (callers mask), ``.at[].set`` touches only the owning
    shard's buffer (copy-on-write)."""
    _, X, _, _ = setup
    Xn = np.asarray(X[:512])
    store = rerank_tier.demote(Xn, shards=4)
    ids = np.array([[0, 127, 128, 511], [-1, 300, 5, 400]], np.int32)
    np.testing.assert_array_equal(store.take(ids),
                                  Xn[np.maximum(ids, 0)])
    rows = np.full((2, Xn.shape[1]), 7.0, np.float32)
    store2 = store.at[np.array([3, 200])].set(rows)
    np.testing.assert_array_equal(store2.take(np.array([[3, 200]])),
                                  rows[None])
    # original untouched; non-owning shards share buffers (no n*D copy)
    np.testing.assert_array_equal(store.take(np.array([[3, 200]])),
                                  Xn[None, [3, 200]])
    assert store2.shards[2] is store.shards[2]


def test_host_store_is_leafless_aux(setup):
    """The pytree contract behind zero-recompile swaps, enforced by the
    registry's ONE definition (``LeaflessAuxHostTier``): HostStore and
    ShardedHostStore contribute NO leaves, aux equality is the store's
    (shape, dtype) aval -- content-stable, shape-guarded -- and
    demote/promote round-trips the rows exactly. Plus the ``.at[].set``
    path this module owns: an updated store stays treedef-equal too."""
    from repro.analysis import assert_rules
    from repro.analysis.protocol_rules import LeaflessAuxHostTier

    _, X, _, _ = setup

    class Ctx:
        pass

    ctx = Ctx()
    ctx.X = X[:64]
    assert_rules(ctx, [LeaflessAuxHostTier()], target="host-tier")
    a = rerank_tier.demote(np.asarray(X[:64]))
    b = a.at[np.array([0])].set(np.ones((1, X.shape[1]), np.float32))
    assert jax.tree_util.tree_flatten(a)[1] == \
        jax.tree_util.tree_flatten(b)[1]                 # update-stable


def test_rerank_refuses_host_gather_inside_jit(setup):
    ds, X, lin, gvm = setup
    arts = msearch.demote_rerank_tier(
        msearch.build_artifacts("gleanvec", X, gvm))
    q = jnp.asarray(ds.queries_test[:4])

    def traced(cand):
        return msearch.rerank(q, arts, cand, K)

    with pytest.raises(TypeError, match="state_candidates"):
        jax.jit(traced)(jnp.zeros((4, KAPPA), jnp.int32))


# ---------------------------------------------------------------------------
# EDGE CASES: dead candidate rows, kappa > n, k > n -- both tiers agree.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["full", "gleanvec-int8-sorted"])
def test_rerank_all_dead_candidate_row(setup, mode):
    """A query whose candidate row is entirely -1 (nothing survived the
    main search) returns all -1 from the rerank -- never row 0's id --
    on the device tier AND through the host gather (which clamps -1 to
    row 0 internally and relies on the mask)."""
    ds, X, lin, gvm = setup
    arts = msearch.build_artifacts(mode, X, _model_for(mode, lin, gvm))
    q = jnp.asarray(ds.queries_test[:3])
    cand = np.tile(np.arange(KAPPA, dtype=np.int32), (3, 1))
    cand[1, :] = -1                                   # dead row
    cand[2, K - 2:] = -1                              # < k live candidates
    ref = np.asarray(msearch.rerank(q, arts, jnp.asarray(cand), K))
    got = np.asarray(msearch.rerank(
        q, msearch.demote_rerank_tier(arts), cand, K))
    np.testing.assert_array_equal(got, ref, mode)
    assert (got[1] == -1).all(), mode
    assert (got[2, -2:] == -1).all() and (got[2, :-2] >= 0).all(), mode


@pytest.mark.parametrize("regime", ["id", "ood"])
@pytest.mark.parametrize("index_kind", ["flat", "graph"])
def test_kappa_and_k_exceed_n(setup, regime, index_kind):
    """kappa > n (the whole database fits in one candidate set) and
    k > n: both tiers return every live id exactly once and pad the tail
    with -1, identically."""
    ds, X, lin, gvm = setup
    n_small, k, kappa = 40, 50, 64
    Xs = X[:n_small]
    arts = msearch.SearchArtifacts(
        scorer=sc.build_scorer("gleanvec", Xs, gvm), x_full=Xs, model=gvm)
    index = None
    if index_kind == "graph":
        g = graph.build(np.asarray(Xs), r=8, n_iters=3, seed=0)
        index = replace(g, beam=32, max_hops=48, expand=4)
    q = jnp.asarray(ds.queries_test[:8] if regime == "ood"
                    else ds.database[:8])
    ref = np.asarray(msearch.state_search(
        q, msearch.make_state(arts, index=index, block=256), k, kappa))
    got = np.asarray(_host_search(msearch.demote_rerank_tier(arts), q, k,
                                  kappa, index=index))
    np.testing.assert_array_equal(got, ref,
                                  err_msg=f"{index_kind}/{regime}")
    assert got.shape == (8, k)
    for row in got:
        live = row[row >= 0]
        assert len(set(live.tolist())) == len(live)   # no duplicate ids
        assert (row[len(live):] == -1).all()          # -1 tail padding
    if index_kind == "flat":        # exhaustive scan: all n rows surface
        assert all((r >= 0).sum() == n_small for r in got)


# ---------------------------------------------------------------------------
# SERVING: pipelined engine parity, byte accounting, zero-recompile swaps,
# guarded swaps, snapshot/restore.
# ---------------------------------------------------------------------------

D, N, N0, CAP, BATCH = 32, 512, 384, 512, 16


@pytest.fixture(scope="module")
def serve_env():
    ds = vectors.make_dataset("rerank-serve", n=N, d=D, n_queries=256,
                              ood=True, seed=9)
    X = jnp.asarray(ds.database)
    rng = np.random.default_rng(0)
    q_init = np.asarray(X)[rng.integers(0, N0, 256)] \
        + 0.1 * rng.standard_normal((256, D)).astype(np.float32)
    model = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:N0],
                   c=4, d=8)
    return ds, X, q_init, model


def _streaming_arts(env, host_rerank):
    _, X, _, model = env
    return streaming.build_streaming_artifacts(
        "gleanvec-int8", X[:N0], model, capacity=CAP, sort_block=64,
        slack_blocks=2, host_rerank=host_rerank)


def _engine(arts):
    return ServingEngine(msearch.make_state(arts), k=K, kappa=KAPPA,
                         batch_size=BATCH, dim=D)


def test_engine_pipeline_parity_and_byte_accounting(serve_env):
    """The double-buffered submit == the all-HBM engine on identical
    traffic, and the measured host->device traffic is EXACTLY
    batches*batch*kappa*D*4 bytes -- the m*kappa*D*4 contract with batch
    padding as the only slack, nothing proportional to n*D."""
    ds = serve_env[0]
    QT = np.asarray(ds.queries_test)
    e_hbm, e_host = _engine(_streaming_arts(serve_env, False)), \
        _engine(_streaming_arts(serve_env, True))
    assert msearch.host_tier(e_host.state.artifacts) is not None
    for q in (QT[:4 * BATCH], QT[: BATCH // 2], QT[: 3 * BATCH + 5]):
        np.testing.assert_array_equal(e_host.submit(q), e_hbm.submit(q))
    s = e_host.stats
    itemsize = 4
    assert s.host_bytes == s.host_bytes_lb \
        == s.n_batches * BATCH * KAPPA * D * itemsize
    assert s.host_bytes_ratio == 1.0
    assert len(s.prefetch_ms) == s.n_batches
    assert e_hbm.stats.host_bytes == 0       # single-tier engine: no traffic


def test_engine_swap_zero_recompiles_host_tier(serve_env, compile_counter):
    """Streaming cycles (insert + refresh + swap) over a host-tier store:
    the leafless-aux treedef survives every refresh, so after the warmup
    cycle there are ZERO XLA compiles -- and the store is still a
    HostStore (never silently promoted) serving correct results."""
    ds, X, q_init, model = serve_env
    engine = _engine(_streaming_arts(serve_env, True))
    stream = streaming.init_from_artifacts(engine.state.artifacts,
                                           jnp.asarray(q_init),
                                           refresh_every=64)
    QT = np.asarray(ds.queries_test)
    step = (CAP - N0) // 4

    def cycle(i):
        nonlocal stream
        engine.submit(QT[i * BATCH:(i + 1) * BATCH])
        rows = X[N0 + i * step: N0 + (i + 1) * step]
        arts2, _ = streaming.insert_rows(engine.state.artifacts, rows)
        engine.swap(engine.state._replace(artifacts=arts2))
        stream = streaming.observe_queries(
            stream, jnp.asarray(QT[i * 64:(i + 1) * 64]))
        stream = streaming.insert(stream, rows)
        stream = streaming.refresh(stream)
        engine.swap(streaming.refresh_state(engine.state, stream,
                                            source="full"))

    cycle(0)                                 # warmup
    compile_counter.reset()
    cycle(1)
    cycle(2)
    served = engine.submit(QT[:2 * BATCH])
    assert compile_counter.count == 0, \
        f"{compile_counter.count} recompiles across host-tier swap cycles"
    assert engine.n_swaps == 6
    store = msearch.host_tier(engine.state.artifacts)
    assert store is not None and len(store) == CAP
    # the streamed host store serves EXACTLY what its promoted (all-HBM)
    # twin would -- inserts and refreshes reached the host rows
    state_dev = engine.state._replace(
        artifacts=msearch.promote_rerank_tier(engine.state.artifacts))
    ref = msearch.state_search(jnp.asarray(QT[:2 * BATCH], jnp.float32),
                               state_dev, K, KAPPA)
    np.testing.assert_array_equal(served, np.asarray(ref))


def test_guarded_swaps_on_host_tier(serve_env, compile_counter):
    """GuardedEngine over a pipelined host-tier engine: the canary
    battery runs through the two-stage path, corrupt states are rejected
    atomically (bit-identical serving after), and a legitimate refresh is
    accepted with zero recompiles."""
    ds, X, q_init, model = serve_env
    engine = _engine(_streaming_arts(serve_env, True))
    guarded = lifecycle.GuardedEngine(
        engine, canary_queries=np.asarray(ds.queries_test)[:BATCH])
    obs = np.asarray(ds.queries_test)[BATCH:2 * BATCH]
    before = guarded.submit(obs)
    state0, swaps0 = engine.state, engine.n_swaps
    with pytest.raises(lifecycle.SwapRejected) as ei:
        guarded.swap(faults.corrupt_scorer_leaf(engine.state))
    assert ei.value.reason == "non-finite"
    assert engine.state is state0 and engine.n_swaps == swaps0
    np.testing.assert_array_equal(guarded.submit(obs), before)
    # a legitimate refresh passes the guards, zero recompiles
    stream = streaming.init_from_artifacts(engine.state.artifacts,
                                           jnp.asarray(q_init),
                                           refresh_every=64)
    stream = streaming.observe_queries(stream, jnp.asarray(obs))
    stream = streaming.refresh(stream)
    compile_counter.reset()
    guarded.swap(streaming.refresh_state(engine.state, stream,
                                         source="full"))
    assert engine.n_swaps == swaps0 + 1
    assert compile_counter.count == 0
    assert msearch.host_tier(engine.state.artifacts) is not None


def test_snapshot_restore_roundtrips_host_tier(serve_env, tmp_path,
                                               compile_counter):
    """snapshot/restore carries the host store through the manifest
    (``host_full``) and rebinds it on restore: the restored state serves
    bit-identical results, still host-resident, with zero recompiles on
    the original engine."""
    ds, X, q_init, model = serve_env
    engine = _engine(_streaming_arts(serve_env, True))
    stream = streaming.init_from_artifacts(engine.state.artifacts,
                                           jnp.asarray(q_init),
                                           refresh_every=64)
    probe = np.asarray(ds.queries_test)[:2 * BATCH]
    before = engine.submit(probe)
    lifecycle.snapshot(str(tmp_path), engine.state, stream)
    restored, _, step, _ = lifecycle.restore(str(tmp_path), engine.state,
                                             stream)
    store = msearch.host_tier(restored.artifacts)
    assert store is not None                 # restored ON the host tier
    np.testing.assert_array_equal(np.asarray(store),
                                  np.asarray(msearch.host_tier(
                                      engine.state.artifacts)))
    compile_counter.reset()
    engine.swap(restored._replace(
        version=engine.state.version + 1))
    np.testing.assert_array_equal(engine.submit(probe), before)
    assert compile_counter.count == 0
