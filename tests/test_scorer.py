"""Unified Scorer protocol: legacy-entry-point equivalence, kernel-lowering
equivalence, and index parity (IVF / graph with every scorer vs. the
bruteforce reference) on synthetic ID and OOD query sets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import quantization as quant
from repro.core import scorer as sc
from repro.core import search as msearch
from repro.index import bruteforce, graph, ivf
from repro.data import vectors

pytestmark = pytest.mark.tier1

D_LOW = 24
C = 8
K = 10
KAPPA = 60


@pytest.fixture(scope="module", params=["ood", "id"])
def setup(request):
    """Dataset + models + all scorers + indices, once per query regime."""
    ood = request.param == "ood"
    ds = vectors.make_dataset(f"scorer-{request.param}", n=3000, d=64,
                              n_queries=96, ood=ood, seed=5)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    lin = lvs.fit(Q, X, D_LOW)
    gvm = gv.fit(jax.random.PRNGKey(0), Q, X, c=C, d=D_LOW)
    scorers = {
        "full": sc.exact_scorer(X),
        "sphering": sc.linear_scorer(lin, X),
        "gleanvec": sc.gleanvec_scorer(gvm, X),
        "sphering-int8": sc.quantized_scorer(lin, X),
        "gleanvec-int8": sc.gleanvec_quantized_scorer(gvm, X),
    }
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=16)
    g = graph.build(ds.database, r=20, n_iters=4, seed=0)
    return ds, X, lin, gvm, scorers, iv, g


def _recall_after_rerank(ds, X, cand, k=K):
    QT = jnp.asarray(ds.queries_test)
    art = msearch.SearchArtifacts(scorer=sc.exact_scorer(X), x_full=X)
    ids = msearch.rerank(QT, art, cand, k)
    return float(metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :k])))


def test_legacy_entry_points_equal_scorer_path(setup):
    """The historical bruteforce signatures and the protocol path are the
    same blocked scan -- bit-identical results."""
    ds, X, lin, gvm, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test)

    v1, i1 = bruteforce.search(QT @ lin.a.T, X @ lin.b.T, K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers["sphering"], K, block=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                               atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))

    tags, x_low = gv.encode_database(gvm, X)
    q_views = gv.project_queries_eager(gvm, QT)
    v1, i1 = bruteforce.search_gleanvec(q_views, tags, x_low, K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers["gleanvec"], K, block=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))

    db = quant.quantize(X @ lin.b.T)
    v1, i1 = bruteforce.search_quantized(QT @ lin.a.T, db.codes, db.lo,
                                         db.delta, K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers["sphering-int8"], K,
                                      block=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_kernel_lowering_matches_scan(setup):
    """repro.kernels.scorer_topk (the kernel dispatch point) agrees with the
    protocol's blocked scan for every scorer."""
    from repro import kernels
    ds, X, _, _, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test[:16])
    for name, s in scorers.items():
        v1, i1 = kernels.scorer_topk(s, QT, K)
        v2, i2 = bruteforce.search_scorer(QT, s, K, block=512)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                                   rtol=1e-4, atol=1e-4, err_msg=name)
        assert np.array_equal(np.asarray(i1), np.asarray(i2)), name


def test_per_cluster_quantization_tight(setup):
    """GleanVec ∘ int8 scores track the unquantized GleanVec scores within
    the per-cluster quantization step bound."""
    ds, X, _, gvm, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test[:8])
    sq = scorers["gleanvec-int8"]
    sgl = scorers["gleanvec"]
    ids = jnp.arange(256)[None, :].repeat(QT.shape[0], axis=0)
    exact = sgl.score_ids(sgl.prepare_queries(QT), ids)
    approx = sq.score_ids(sq.prepare_queries(QT), ids)
    err = np.abs(np.asarray(exact) - np.asarray(approx))
    scale = np.abs(np.asarray(exact)).max()
    assert err.max() / scale < 0.02


@pytest.mark.parametrize("mode", ["sphering", "gleanvec", "sphering-int8",
                                  "gleanvec-int8"])
def test_ivf_parity_with_bruteforce(setup, mode):
    """IVF through any scorer reaches the flat-scan recall - tolerance."""
    ds, X, _, _, scorers, iv, _ = setup
    QT = jnp.asarray(ds.queries_test)
    s = scorers[mode]
    _, flat_cand = bruteforce.search_scorer(QT, s, KAPPA, block=512)
    r_flat = _recall_after_rerank(ds, X, flat_cand)
    _, ivf_cand = ivf.search_scorer(QT, s, iv, k=KAPPA, nprobe=8)
    r_ivf = _recall_after_rerank(ds, X, ivf_cand)
    assert r_flat > 0.85, (mode, r_flat)
    assert r_ivf >= r_flat - 0.15, (mode, r_flat, r_ivf)


@pytest.mark.parametrize("mode", ["sphering", "gleanvec", "sphering-int8",
                                  "gleanvec-int8"])
def test_graph_parity_with_bruteforce(setup, mode):
    """Graph beam search through any scorer reaches the flat-scan recall -
    tolerance."""
    ds, X, _, _, scorers, _, g = setup
    QT = jnp.asarray(ds.queries_test)
    s = scorers[mode]
    _, flat_cand = bruteforce.search_scorer(QT, s, KAPPA, block=512)
    r_flat = _recall_after_rerank(ds, X, flat_cand)
    _, g_cand = graph.beam_search_scorer(QT, s, g, k=KAPPA, beam=96,
                                         max_hops=250)
    r_graph = _recall_after_rerank(ds, X, g_cand)
    assert r_graph >= r_flat - 0.15, (mode, r_flat, r_graph)


def test_graph_trace_through_protocol(setup):
    """trace=True on a tagged scorer returns the Figure-7 tag history."""
    ds, X, _, _, scorers, _, g = setup
    QT = jnp.asarray(ds.queries_test[:8])
    _, ids, hops, tag_hist = graph.beam_search_scorer(
        QT, scorers["gleanvec"], g, k=K, beam=64, max_hops=120, trace=True)
    th = np.asarray(tag_hist)
    assert th.shape == (8, 120) and (th < C).all() and int(hops) > 0
    with pytest.raises(ValueError):
        graph.beam_search_scorer(QT, scorers["sphering"], g, k=K,
                                 trace=True)


def test_multi_step_search_all_modes(setup):
    """Algorithm 1 end-to-end through build_artifacts for every mode."""
    ds, X, lin, gvm, _, _, _ = setup
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :K])

    def index_search(q_low, art, kappa):
        _, cand = bruteforce.scan_scorer(art.scorer, q_low, kappa, 512)
        return cand

    for mode, model in [("full", None), ("sphering", lin),
                        ("gleanvec", gvm), ("sphering-int8", lin),
                        ("gleanvec-int8", gvm)]:
        art = msearch.build_artifacts(mode, X, model)
        ids = msearch.multi_step_search(QT, art, index_search, K, KAPPA)
        rec = float(metrics.recall_at_k(ids, gt))
        assert rec > 0.9, (mode, rec)
