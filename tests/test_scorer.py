"""Unified Scorer protocol: legacy-entry-point equivalence, kernel-lowering
equivalence, and index parity (IVF / graph with every scorer vs. the
bruteforce reference) on synthetic ID and OOD query sets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import quantization as quant
from repro.core import scorer as sc
from repro.core import search as msearch
from repro.index import bruteforce, graph, ivf
from repro.data import vectors

pytestmark = pytest.mark.tier1

D_LOW = 24
C = 8
K = 10
KAPPA = 60


@pytest.fixture(scope="module", params=["ood", "id"])
def setup(request):
    """Dataset + models + all scorers + indices, once per query regime."""
    ood = request.param == "ood"
    ds = vectors.make_dataset(f"scorer-{request.param}", n=3000, d=64,
                              n_queries=96, ood=ood, seed=5)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    lin = lvs.fit(Q, X, D_LOW)
    gvm = gv.fit(jax.random.PRNGKey(0), Q, X, c=C, d=D_LOW)
    scorers = {
        "full": sc.exact_scorer(X),
        "sphering": sc.linear_scorer(lin, X),
        "gleanvec": sc.gleanvec_scorer(gvm, X),
        "sphering-int8": sc.quantized_scorer(lin, X),
        "gleanvec-int8": sc.gleanvec_quantized_scorer(gvm, X),
        "gleanvec-sorted": sc.sorted_gleanvec_scorer(gvm, X, block=256),
        "gleanvec-int8-sorted": sc.sorted_gleanvec_quantized_scorer(
            gvm, X, block=256),
    }
    iv = ivf.build(jax.random.PRNGKey(1), X, n_lists=16)
    g = graph.build(ds.database, r=20, n_iters=4, seed=0)
    return ds, X, lin, gvm, scorers, iv, g


def _recall_after_rerank(ds, X, cand, k=K):
    QT = jnp.asarray(ds.queries_test)
    art = msearch.SearchArtifacts(scorer=sc.exact_scorer(X), x_full=X)
    ids = msearch.rerank(QT, art, cand, k)
    return float(metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :k])))


def test_legacy_entry_points_equal_scorer_path(setup):
    """The historical bruteforce signatures and the protocol path are the
    same blocked scan -- bit-identical results."""
    ds, X, lin, gvm, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test)

    v1, i1 = bruteforce.search(QT @ lin.a.T, X @ lin.b.T, K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers["sphering"], K, block=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5,
                               atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))

    tags, x_low = gv.encode_database(gvm, X)
    q_views = gv.project_queries_eager(gvm, QT)
    v1, i1 = bruteforce.search_gleanvec(q_views, tags, x_low, K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers["gleanvec"], K, block=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))

    db = quant.quantize(X @ lin.b.T)
    v1, i1 = bruteforce.search_quantized(QT @ lin.a.T, db.codes, db.lo,
                                         db.delta, K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers["sphering-int8"], K,
                                      block=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_kernel_lowering_matches_scan(setup):
    """repro.kernels.scorer_topk (the kernel dispatch point) agrees with the
    protocol's blocked scan for every scorer."""
    from repro import kernels
    ds, X, _, _, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test[:16])
    for name, s in scorers.items():
        v1, i1 = kernels.scorer_topk(s, QT, K)
        v2, i2 = bruteforce.search_scorer(QT, s, K, block=512)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                                   rtol=1e-4, atol=1e-4, err_msg=name)
        assert np.array_equal(np.asarray(i1), np.asarray(i2)), name


def test_per_cluster_quantization_tight(setup):
    """GleanVec ∘ int8 scores track the unquantized GleanVec scores within
    the per-cluster quantization step bound."""
    ds, X, _, gvm, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test[:8])
    sq = scorers["gleanvec-int8"]
    sgl = scorers["gleanvec"]
    ids = jnp.arange(256)[None, :].repeat(QT.shape[0], axis=0)
    exact = sgl.score_ids(sgl.prepare_queries(QT), ids)
    approx = sq.score_ids(sq.prepare_queries(QT), ids)
    err = np.abs(np.asarray(exact) - np.asarray(approx))
    scale = np.abs(np.asarray(exact)).max()
    assert err.max() / scale < 0.02


@pytest.mark.parametrize("pair", [("gleanvec", "gleanvec-sorted"),
                                  ("gleanvec-int8", "gleanvec-int8-sorted")])
def test_sorted_flat_scan_matches_unsorted(setup, pair):
    """The tag-sorted layout is a LAYOUT, not a scoring mode: the flat scan
    returns the same (value, id) sets as the row-aligned scorer once ids
    are translated through the permutation (which the protocol does
    internally)."""
    base, srt = pair
    ds, X, _, _, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test)
    v1, i1 = bruteforce.search_scorer(QT, scorers[base], K, block=512)
    v2, i2 = bruteforce.search_scorer(QT, scorers[srt], K)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.sort(np.asarray(i1), 1),
                          np.sort(np.asarray(i2), 1))
    n = X.shape[0]
    ids = np.asarray(i2)
    assert ids.min() >= 0 and ids.max() < n   # original space, no padding


@pytest.mark.parametrize("pair", [("gleanvec", "gleanvec-sorted"),
                                  ("gleanvec-int8", "gleanvec-int8-sorted")])
def test_sorted_ivf_matches_unsorted(setup, pair):
    """IVF posting lists speak original ids; sorted scorers gather through
    inv_perm inside score_ids and return identical candidates."""
    base, srt = pair
    ds, _, _, _, scorers, iv, _ = setup
    QT = jnp.asarray(ds.queries_test)
    v1, i1 = ivf.search_scorer(QT, scorers[base], iv, k=K, nprobe=8)
    v2, i2 = ivf.search_scorer(QT, scorers[srt], iv, k=K, nprobe=8)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert np.array_equal(np.sort(np.asarray(i1), 1),
                          np.sort(np.asarray(i2), 1))


def test_sorted_score_ids_matches_unsorted(setup):
    """score_ids on arbitrary ORIGINAL id sets: sorted == row-aligned (the
    graph beam expansion path)."""
    ds, X, _, _, scorers, _, _ = setup
    QT = jnp.asarray(ds.queries_test[:8])
    ids = jnp.asarray(
        np.random.default_rng(3).integers(0, X.shape[0], (8, 64)))
    for base, srt in [("gleanvec", "gleanvec-sorted"),
                      ("gleanvec-int8", "gleanvec-int8-sorted")]:
        sb, ss = scorers[base], scorers[srt]
        a = sb.score_ids(sb.prepare_queries(QT), ids)
        b = ss.score_ids(ss.prepare_queries(QT), ids)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4, err_msg=base)


def test_sorted_build_scorer_modes(setup):
    """Mode strings cover the sorted layouts and translate contract holds."""
    ds, X, _, gvm, _, _, _ = setup
    assert "gleanvec-sorted" in sc.MODES
    assert "gleanvec-int8-sorted" in sc.MODES
    s = sc.build_scorer("gleanvec-sorted", X, gvm)
    assert isinstance(s, sc.SortedGleanVecScorer)
    sq = sc.build_scorer("gleanvec-int8-sorted", X, gvm)
    assert isinstance(sq, sc.SortedGleanVecQuantizedScorer)
    # translate_ids: sorted rows -> original ids; padding -> -1
    rows = jnp.asarray([0, s.n_rows - 1, -1])
    out = np.asarray(s.translate_ids(rows))
    assert out[2] == -1 and (out[:2] < X.shape[0]).all()
    # pad_rows must refuse to break the pre-padded block structure
    with pytest.raises(ValueError):
        s.pad_rows(7)


@pytest.mark.parametrize("mode", ["sphering", "gleanvec", "sphering-int8",
                                  "gleanvec-int8"])
def test_ivf_parity_with_bruteforce(setup, mode):
    """IVF through any scorer reaches the flat-scan recall - tolerance."""
    ds, X, _, _, scorers, iv, _ = setup
    QT = jnp.asarray(ds.queries_test)
    s = scorers[mode]
    _, flat_cand = bruteforce.search_scorer(QT, s, KAPPA, block=512)
    r_flat = _recall_after_rerank(ds, X, flat_cand)
    _, ivf_cand = ivf.search_scorer(QT, s, iv, k=KAPPA, nprobe=8)
    r_ivf = _recall_after_rerank(ds, X, ivf_cand)
    assert r_flat > 0.85, (mode, r_flat)
    assert r_ivf >= r_flat - 0.15, (mode, r_flat, r_ivf)


@pytest.mark.parametrize("mode", ["sphering", "gleanvec", "sphering-int8",
                                  "gleanvec-int8", "gleanvec-sorted",
                                  "gleanvec-int8-sorted"])
def test_graph_parity_with_bruteforce(setup, mode):
    """Graph beam search through any scorer reaches the flat-scan recall -
    tolerance."""
    ds, X, _, _, scorers, _, g = setup
    QT = jnp.asarray(ds.queries_test)
    s = scorers[mode]
    _, flat_cand = bruteforce.search_scorer(QT, s, KAPPA, block=512)
    r_flat = _recall_after_rerank(ds, X, flat_cand)
    _, g_cand = graph.beam_search_scorer(QT, s, g, k=KAPPA, beam=96,
                                         max_hops=250)
    r_graph = _recall_after_rerank(ds, X, g_cand)
    assert r_graph >= r_flat - 0.15, (mode, r_flat, r_graph)


def test_graph_trace_through_protocol(setup):
    """trace=True on a tagged scorer returns the Figure-7 tag history."""
    ds, X, _, _, scorers, _, g = setup
    QT = jnp.asarray(ds.queries_test[:8])
    _, ids, hops, tag_hist = graph.beam_search_scorer(
        QT, scorers["gleanvec"], g, k=K, beam=64, max_hops=120, trace=True)
    th = np.asarray(tag_hist)
    assert th.shape == (8, 120) and (th < C).all() and int(hops) > 0
    with pytest.raises(ValueError):
        graph.beam_search_scorer(QT, scorers["sphering"], g, k=K,
                                 trace=True)


def test_multi_step_search_all_modes(setup):
    """Algorithm 1 end-to-end through build_artifacts for every mode."""
    ds, X, lin, gvm, _, _, _ = setup
    QT = jnp.asarray(ds.queries_test)
    gt = jnp.asarray(ds.gt[:, :K])

    def index_search(q_low, art, kappa):
        _, cand = bruteforce.scan_scorer(art.scorer, q_low, kappa, 512)
        return cand

    for mode, model in [("full", None), ("sphering", lin),
                        ("gleanvec", gvm), ("sphering-int8", lin),
                        ("gleanvec-int8", gvm), ("gleanvec-sorted", gvm),
                        ("gleanvec-int8-sorted", gvm)]:
        art = msearch.build_artifacts(mode, X, model)
        ids = msearch.multi_step_search(QT, art, index_search, K, KAPPA)
        rec = float(metrics.recall_at_k(ids, gt))
        assert rec > 0.9, (mode, rec)
