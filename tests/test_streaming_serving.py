"""Streaming serving (Section 3.2) x the state-passing engine.

Three layers of guarantees:

* MATH -- streamed moments + ``refresh`` match a from-scratch batch refit,
  and Eq. 12 reprojection (linear AND per-cluster, eager and lazy
  ``pending``) matches direct re-projection at d == D where it is exact;
* SYSTEM -- one ``ServingEngine`` serves EVERY scorer mode through >= 3
  full streaming cycles (observe -> insert -> refresh -> swap) with ZERO
  XLA recompilations after warmup, asserted by the ``compile_counter``
  fixture AND the engine's own executable cache size;
* QUALITY -- on a drifted (OOD) query distribution, the refreshed model's
  recall@10 beats the stale (pre-drift) model's on the same grown
  database, for the gleanvec / gleanvec-int8 / gleanvec-int8-sorted
  serving modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gleanvec as gv, leanvec_sphering as lvs, linalg, \
    metrics, streaming
from repro.core import search as msearch
from repro.core.scorer import MODES
from repro.data import vectors
from repro.index import ivf
from repro.serve import retrieval
from repro.serve.engine import ServingEngine

pytestmark = pytest.mark.tier1

D = 64
N, N0, CAP = 1024, 768, 1024
STEP, CYCLES = 64, 3


@pytest.fixture(scope="module")
def setup():
    ds = vectors.make_dataset("stream-serve", n=N, d=D, n_queries=512,
                              ood=True, seed=3)
    X = jnp.asarray(ds.database)
    rng = np.random.default_rng(0)
    # the t=0 model is fit on ID (database-like) queries; the live traffic
    # (ds.queries_learn / ds.queries_test) is OOD -- the Figure-1 drift
    q_init = np.asarray(X)[rng.integers(0, N0, 256)] \
        + 0.1 * rng.standard_normal((256, D)).astype(np.float32)
    gvm = gv.fit(jax.random.PRNGKey(0), jnp.asarray(q_init), X[:N0],
                 c=4, d=8)
    lin = lvs.fit(jnp.asarray(q_init), X[:N0], 8)
    return ds, X, q_init, gvm, lin


def _model_for(mode, gvm, lin):
    if mode == "full":
        return None
    return lin if mode.startswith("sphering") else gvm


def _run_cycles(engine, stream, ds, X, cycles, on_insert=None):
    """The streaming lifecycle: serve OOD traffic, observe it, insert the
    cycle's rows, refresh, swap -- once per index in ``cycles``. Returns
    the stream state."""
    obs_pool = np.asarray(ds.queries_learn)
    for cycle in cycles:
        obs = obs_pool[cycle * 128:cycle * 128 + 128]
        engine.submit(obs[:32])
        rows = X[N0 + cycle * STEP: N0 + (cycle + 1) * STEP]
        arts2, new_ids = streaming.insert_rows(engine.state.artifacts, rows)
        state2 = engine.state._replace(artifacts=arts2)
        if on_insert is not None:
            state2 = on_insert(state2, rows, new_ids)
        engine.swap(state2)
        if stream is not None:
            stream = streaming.observe_queries(stream, jnp.asarray(obs))
            stream = streaming.insert(stream, rows)
            assert bool(streaming.needs_refresh(stream))
            stream = streaming.refresh(stream)
        engine.swap(streaming.refresh_state(engine.state, stream,
                                            source="full"))
    return stream


# ---------------------------------------------------------------------------
# MATH: streamed moments == batch refit; Eq. 12 == direct re-projection.
# ---------------------------------------------------------------------------


def test_streaming_gleanvec_matches_batch(setup):
    """Per-cluster K_X under batched rank-1 inserts/removes + refresh ==
    a from-scratch ``gleanvec.fit_from_moments`` refit on the effective
    set (same fixed landmarks)."""
    ds, X, q_init, gvm, _ = setup
    c = gvm.n_clusters
    x0 = X[:500]
    tags0 = streaming._assign(gvm, x0)
    k_q = linalg.second_moment(jnp.asarray(q_init))
    st = streaming.init_gleanvec(gvm, k_q,
                                 gv.per_cluster_moments(x0, tags0, c),
                                 refresh_every=100)
    st = streaming.insert(st, X[500:560])
    st = streaming.remove(st, X[:40])
    obs = jnp.asarray(ds.queries_learn[:128])
    st = streaming.observe_queries(st, obs)
    assert int(st.updates_since) == 100
    st = streaming.refresh(st)
    assert int(st.updates_since) == 0
    # reference: batch moments of the effective set X[40:560]
    x_eff = X[40:560]
    tags_eff = streaming._assign(gvm, x_eff)
    k_x_ref = gv.per_cluster_moments(x_eff, tags_eff, c)
    np.testing.assert_allclose(np.asarray(st.k_x), np.asarray(k_x_ref),
                               rtol=2e-2, atol=2e-1)
    m_ref = gv.fit_from_moments(gvm.centers, k_q + linalg.second_moment(obs),
                                k_x_ref, gvm.dim)
    # same moments -> same per-cluster fits: compare via scores (the
    # eigendecomposition is sign/rotation free, scores are not)
    q = jnp.asarray(ds.queries_test[:16])
    qv1 = np.asarray(gv.project_queries_eager(st.model, q))   # (m, C, d)
    qv2 = np.asarray(gv.project_queries_eager(m_ref, q))
    t1, l1 = gv.encode_database(st.model, x_eff[:64])
    t2, l2 = gv.encode_database(m_ref, x_eff[:64])
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    s1 = np.einsum("mnd,nd->mn", qv1[:, np.asarray(t1)], np.asarray(l1))
    s2 = np.einsum("mnd,nd->mn", qv2[:, np.asarray(t2)], np.asarray(l2))
    np.testing.assert_allclose(s1, s2, rtol=5e-2, atol=0.5)


@pytest.mark.parametrize("regime", ["id", "ood"])
def test_streaming_reproject_matches_direct_linear(setup, regime):
    """Eq. 12 at d == D (full-rotation storage): reprojection of stored
    vectors == direct projection under the refreshed model, for ID and
    OOD query moments; the lazy ``pending`` path touches exactly the
    marked rows."""
    ds, X, q_init, _, _ = setup
    x = X[:300]
    q = jnp.asarray(q_init if regime == "id"
                    else np.asarray(ds.queries_learn[:256]))
    st = streaming.init(linalg.second_moment(q), linalg.second_moment(x),
                        d=D, refresh_every=10)
    x_low = x @ st.model.b.T
    st = streaming.insert(st, x[:12] * 1.5)
    st = streaming.observe_queries(st,
                                   jnp.asarray(ds.queries_learn[256:384]))
    st = streaming.refresh(st)
    direct = x @ st.model.b.T
    reproj = streaming.reproject(st, x_low)
    np.testing.assert_allclose(np.asarray(reproj), np.asarray(direct),
                               rtol=1e-2, atol=1e-2)
    pending = jnp.arange(300) % 2 == 0
    lazy = streaming.reproject(st, x_low, pending=pending)
    np.testing.assert_allclose(np.asarray(lazy),
                               np.where(np.asarray(pending)[:, None],
                                        np.asarray(reproj),
                                        np.asarray(x_low)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("regime", ["id", "ood"])
def test_streaming_reproject_matches_direct_gleanvec(setup, regime):
    """Per-cluster Eq. 12 at d == D: T_{c} maps each cluster's stored
    vectors onto the refreshed per-cluster projection exactly."""
    ds, X, q_init, _, _ = setup
    x = X[:400]
    q = jnp.asarray(q_init if regime == "id"
                    else np.asarray(ds.queries_learn[:256]))
    model = gv.fit(jax.random.PRNGKey(1), q, x, c=3, d=D)   # d == D
    tags, x_low = gv.encode_database(model, x)
    st = streaming.init_gleanvec(
        model, linalg.second_moment(q),
        gv.per_cluster_moments(x, tags, 3), refresh_every=10)
    st = streaming.insert(st, x[:16] * 1.5)
    st = streaming.observe_queries(st,
                                   jnp.asarray(ds.queries_learn[256:384]))
    st = streaming.refresh(st)
    assert streaming.transition_matrix(st).shape == (3, D, D)
    _, direct = gv.encode_database(st.model, x)
    reproj = streaming.reproject(st, x_low, tags=tags)
    np.testing.assert_allclose(np.asarray(reproj), np.asarray(direct),
                               rtol=2e-2, atol=2e-2)
    pending = jnp.arange(400) % 3 == 0
    lazy = streaming.reproject(st, x_low, tags=tags, pending=pending)
    np.testing.assert_allclose(np.asarray(lazy),
                               np.where(np.asarray(pending)[:, None],
                                        np.asarray(reproj),
                                        np.asarray(x_low)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SYSTEM: zero recompiles across swaps, for every serving mode.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", list(MODES))
def test_engine_swap_zero_recompiles(setup, mode, compile_counter):
    """One ServingEngine per mode through 3 full streaming cycles
    (observe + insert + refresh + swap): after the warmup cycle, ZERO
    XLA backend compiles -- the compiled step is reused across every
    swap, and the engine's executable cache stays at size 1."""
    ds, X, q_init, gvm, lin = setup
    model = _model_for(mode, gvm, lin)
    arts = streaming.build_streaming_artifacts(mode, X[:N0], model,
                                               capacity=CAP, sort_block=64,
                                               slack_blocks=2)
    engine = ServingEngine(msearch.make_state(arts, block=256), k=10,
                           kappa=15, batch_size=32, dim=D)
    stream = (None if model is None else
              streaming.init_from_artifacts(arts, q_init,
                                            refresh_every=STEP))
    # cycle 0 is the warmup: compiles the serving step AND every eager op
    # of the host-side streaming loop once
    stream = _run_cycles(engine, stream, ds, X, [0])
    compile_counter.reset()
    stream = _run_cycles(engine, stream, ds, X, [1, 2])
    engine.submit(np.asarray(ds.queries_test[:32]))
    assert compile_counter.count == 0, \
        f"{mode}: {compile_counter.count} recompiles across swap cycles"
    assert engine.n_compiles in (None, 1)
    assert engine.n_swaps == 2 * CYCLES
    assert engine.version == 2 * CYCLES


def test_engine_swap_zero_recompiles_ivf_reduced_probe(setup,
                                                       compile_counter):
    """The IVF traversal streams too: posting-list inserts fill
    pre-allocated slack, removals tombstone, and the refresh hook
    re-encodes the reduced-space center companion -- still zero
    recompiles."""
    ds, X, q_init, gvm, _ = setup
    arts = streaming.build_streaming_artifacts("gleanvec-int8", X[:N0],
                                               gvm, capacity=CAP)
    index = ivf.build(jax.random.PRNGKey(1), X[:N0], n_lists=8, nprobe=4)
    index = ivf.with_list_slack(index, CAP - N0)
    index = ivf.with_reduced_centers(index, arts.scorer, gvm)
    engine = ServingEngine(msearch.make_state(arts, index=index), k=10,
                           kappa=15, batch_size=32, dim=D)
    stream = streaming.init_from_artifacts(arts, q_init, refresh_every=STEP)

    def on_insert(state, rows, new_ids):
        return state._replace(index=ivf.insert_ids(state.index, rows,
                                                   new_ids))

    def remove_cycle(rm_ids):
        nonlocal stream
        arts2 = streaming.remove_rows(engine.state.artifacts, rm_ids)
        engine.swap(engine.state._replace(
            artifacts=arts2,
            index=ivf.remove_ids(engine.state.index, rm_ids)))
        stream = streaming.remove(stream, X[jnp.asarray(rm_ids)])
        stream = streaming.refresh(stream)
        engine.swap(streaming.refresh_state(engine.state, stream,
                                            source="full"))

    # warmup: one insert cycle + one remove cycle compile everything once
    stream = _run_cycles(engine, stream, ds, X, [0], on_insert=on_insert)
    remove_cycle(np.arange(8, dtype=np.int32))
    compile_counter.reset()
    stream = _run_cycles(engine, stream, ds, X, [1, 2], on_insert=on_insert)
    remove_cycle(np.arange(8, 16, dtype=np.int32))
    served = engine.submit(np.asarray(ds.queries_test[:32]))
    assert compile_counter.count == 0, \
        f"{compile_counter.count} recompiles across IVF streaming cycles"
    assert engine.state.index.center_scorer is not None
    assert not np.isin(served, np.arange(16)).any()   # tombstones stay dead


def test_engine_swap_refuses_treedef_or_shape_change(setup):
    ds, X, q_init, gvm, _ = setup
    arts = streaming.build_streaming_artifacts("gleanvec", X[:N0], gvm,
                                               capacity=CAP)
    engine = ServingEngine(msearch.make_state(arts, block=256), k=10,
                           kappa=15, batch_size=16, dim=D)
    with pytest.raises(ValueError, match="treedef"):
        engine.swap(msearch.make_state(arts, block=128))   # static config
    grown = arts._replace(x_full=jnp.concatenate(
        [arts.x_full, arts.x_full[:1]]))
    with pytest.raises(ValueError, match="aval"):
        engine.swap(engine.state._replace(artifacts=grown))


# ---------------------------------------------------------------------------
# QUALITY: post-refresh recall on the drifted distribution >= stale model.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["gleanvec", "gleanvec-int8",
                                  "gleanvec-int8-sorted"])
def test_streaming_refresh_beats_stale_model(setup, mode, compile_counter):
    """The acceptance gate: >= 3 cycles of inserts + query observation +
    refresh + swap with zero recompiles after warmup, and the refreshed
    model's recall@10 on the drifted (OOD) distribution >= what the
    stale (pre-drift) model scores on the same grown database."""
    ds, X, q_init, gvm, _ = setup
    arts = streaming.build_streaming_artifacts(mode, X[:N0], gvm,
                                               capacity=CAP, sort_block=64,
                                               slack_blocks=2)
    engine = ServingEngine(msearch.make_state(arts, block=256), k=10,
                           kappa=15, batch_size=32, dim=D)
    stream = streaming.init_from_artifacts(arts, q_init, refresh_every=STEP)
    stream = _run_cycles(engine, stream, ds, X, [0])      # warmup cycle
    compile_counter.reset()
    stream = _run_cycles(engine, stream, ds, X, [1, 2])   # counted cycles
    assert compile_counter.count == 0, \
        f"{mode}: {compile_counter.count} recompiles across refresh cycles"
    assert engine.n_compiles in (None, 1)

    n_final = N0 + CYCLES * STEP
    QT = np.asarray(ds.queries_test)
    gt = vectors.exact_topk(QT, np.asarray(X[:n_final]), 10)
    refreshed_ids = engine.submit(QT)
    r_new = float(metrics.recall_at_k(jnp.asarray(refreshed_ids),
                                      jnp.asarray(gt)))
    stale = msearch.build_artifacts(mode, X[:n_final], gvm)
    stale_ids = msearch.state_search(jnp.asarray(QT),
                                     msearch.make_state(stale, block=256),
                                     10, 15)
    r_stale = float(metrics.recall_at_k(stale_ids, jnp.asarray(gt)))
    assert r_new >= r_stale, (mode, r_stale, r_new)
    assert r_new > 0.85, (mode, r_new)


# ---------------------------------------------------------------------------
# Serving-layer satellites: retrieval compiled-fn cache, row roundtrips.
# ---------------------------------------------------------------------------


def test_retrieval_caches_compiled_fn(setup, compile_counter):
    """retrieve() used to rebuild + re-jit the search fn per call; now the
    compiled step is cached on the RetrievalIndex keyed by
    (k, kappa, treedef) and repeat calls compile NOTHING."""
    ds, X, q_init, gvm, _ = setup
    ri = retrieval.build_retrieval_index(X, "gleanvec-int8", gvm)
    users = jnp.asarray(ds.queries_test[:32])
    ids1 = retrieval.retrieve(ri, users, k=10, kappa=20)
    assert len(ri.fn_cache) == 1
    (key,) = ri.fn_cache
    assert key[0] == 10 and key[1] == 20
    compile_counter.reset()
    ids2 = retrieval.retrieve(ri, users, k=10, kappa=20)
    assert compile_counter.count == 0
    np.testing.assert_array_equal(np.asarray(ids1), np.asarray(ids2))
    # a different (k, kappa) is a new entry, not a clobber
    retrieval.retrieve(ri, users, k=5, kappa=20)
    assert len(ri.fn_cache) == 2


def test_insert_remove_roundtrip_all_modes(setup):
    """Row-level scorer ops: a removed id is never served again; an
    inserted row is retrievable by its own (exact-duplicate) query in
    every mode."""
    ds, X, q_init, gvm, lin = setup
    # the max-norm row is its own exact MIPS top-1 (<x, y> < ||x||^2 for
    # every shorter y), so self-retrieval is well-posed under IP
    rid = int(np.argmax(np.linalg.norm(np.asarray(X[:N0]), axis=1)))
    probe = X[rid][None, :]
    new_row = np.asarray(X[N0 + 1][None, :]) * 3.0   # dominant-norm insert
    for mode in MODES:
        model = _model_for(mode, gvm, lin)
        arts = streaming.build_streaming_artifacts(
            mode, X[:N0], model, capacity=CAP, sort_block=64,
            slack_blocks=2)
        ids0 = msearch.state_search(probe,
                                    msearch.make_state(arts, block=256),
                                    10, 15)
        assert np.isin(rid, np.asarray(ids0[0])), mode
        arts = streaming.remove_rows(arts, jnp.asarray([rid]))
        ids1 = msearch.state_search(probe,
                                    msearch.make_state(arts, block=256),
                                    10, 15)
        assert not np.isin(np.asarray(ids1), [rid]).any(), mode
        arts, new_ids = streaming.insert_rows(arts, new_row)
        ids2 = msearch.state_search(jnp.asarray(new_row),
                                    msearch.make_state(arts, block=256),
                                    10, 15)
        nid = int(np.asarray(new_ids)[0])
        assert np.isin(nid, np.asarray(ids2[0])), mode
        # re-insert at the SAME id == overwrite in every layout: the old
        # encoding must be gone (no ghost slot keeps serving it)
        arts, _ = streaming.insert_rows(arts, np.asarray(X[3][None, :]),
                                        ids=np.asarray([nid]))
        ids3 = msearch.state_search(jnp.asarray(new_row),
                                    msearch.make_state(arts, block=256),
                                    10, 15)
        assert not np.isin(nid, np.asarray(ids3[0])), mode
        if hasattr(arts.scorer, "perm"):
            perm = np.asarray(arts.scorer.perm)
            assert (perm == nid).sum() == 1, mode   # exactly one slot
