"""End-to-end system test: the paper's full pipeline on synthetic OOD data.

learn (Alg. 5) -> encode -> index -> multi-step search (Alg. 1) -> recall,
plus the recsys retrieval integration (GleanVec-accelerated candidate
scoring) and the serving engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gleanvec as gv, leanvec_sphering as lvs, metrics
from repro.core import search as msearch
from repro.data import vectors
from repro.index import graph
from repro.index.protocol import FlatIndex
from repro.serve import retrieval
from repro.serve.engine import ServingEngine


def test_end_to_end_gleanvec_pipeline():
    ds = vectors.make_dataset("e2e", n=5000, d=96, n_queries=96, ood=True,
                              seed=7)
    X = jnp.asarray(ds.database)
    Q = jnp.asarray(ds.queries_learn)
    QT = jnp.asarray(ds.queries_test)

    # 1. learn (Algorithm 5)
    model = gv.fit(jax.random.PRNGKey(0), Q, X, c=12, d=32)
    # 2. encode database (Eq. 14-15)
    tags, x_low = gv.encode_database(model, X)
    # 3. graph index over the reduced vectors
    g = graph.build(np.asarray(x_low), r=24, n_iters=5, seed=0)
    # 4. multi-step search: graph main search (eager, Alg. 4) + rerank
    q_views = gv.project_queries_eager(model, QT)
    _, cand = graph.beam_search_gleanvec(q_views, tags, x_low, g, k=50,
                                         beam=128, max_hops=300)
    cand_vecs = X[jnp.where(cand >= 0, cand, 0)]
    full = jnp.einsum("mkd,md->mk", cand_vecs, QT)
    full = jnp.where(cand >= 0, full, -3.4e38)
    top = jax.lax.top_k(full, 10)[1]
    ids = jnp.take_along_axis(cand, top, axis=1)
    rec = metrics.recall_at_k(ids, jnp.asarray(ds.gt[:, :10]))
    assert float(rec) > 0.85, float(rec)


def test_retrieval_modes_ordering():
    """GleanVec-accelerated retrieval ~ full-precision retrieval."""
    ds = vectors.make_dataset("retr", n=4000, d=64, n_queries=64, ood=True,
                              seed=9)
    cands = jnp.asarray(ds.database)
    users = jnp.asarray(ds.queries_test)
    idx_full = retrieval.build_retrieval_index(cands, "full")
    ids_full = retrieval.retrieve(idx_full, users, k=10)

    model = gv.fit(jax.random.PRNGKey(1), jnp.asarray(ds.queries_learn),
                   cands, c=8, d=24)
    idx_gv = retrieval.build_retrieval_index(cands, "gleanvec", model)
    ids_gv = retrieval.retrieve(idx_gv, users, k=10, kappa=60)

    sph = lvs.fit(jnp.asarray(ds.queries_learn), cands, 24)
    idx_s = retrieval.build_retrieval_index(cands, "sphering", sph)
    ids_s = retrieval.retrieve(idx_s, users, k=10, kappa=60)

    gt = jnp.asarray(ds.gt[:, :10])
    r_full = float(metrics.recall_at_k(ids_full, gt))
    r_gv = float(metrics.recall_at_k(ids_gv, gt))
    r_s = float(metrics.recall_at_k(ids_s, gt))
    assert r_full == 1.0
    assert r_gv > 0.9 and r_s > 0.9
    assert r_gv >= r_s - 0.05  # nonlinear at least matches linear


def test_serving_engine_stats():
    ds = vectors.make_dataset("srv", n=2000, d=32, n_queries=64, ood=False,
                              seed=11)
    X = jnp.asarray(ds.database)

    art = msearch.build_artifacts("full", X)
    state = msearch.make_state(art, index=FlatIndex(block=512))
    eng = ServingEngine(state, k=10, kappa=10, batch_size=16, dim=32)
    out = eng.submit(ds.queries_test[:40])
    assert out.shape == (40, 10)
    assert eng.stats.n_queries == 40
    assert eng.stats.n_batches == 3
    assert eng.stats.qps > 0
    assert eng.stats.percentile_ms(99) >= eng.stats.percentile_ms(50)
    # the exact engine really is exact
    gt = jnp.asarray(ds.gt[:40, :10])
    assert float(metrics.recall_at_k(jnp.asarray(out), gt)) == 1.0
    # state-passing engine: swapping the same-treedef state recompiles
    # nothing and bumps the version counter
    c0 = eng.n_compiles
    eng.swap(eng.state)
    assert eng.version == 1 and eng.n_compiles == c0
