"""Training substrate: convergence, accumulation-equivalence, checkpointing,
data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.sharding import MeshRules
from repro.train import (AdamWConfig, checkpoint, data, make_train_step)
from repro.train.optimizer import adamw_init, cosine_warmup_lr

RULES = MeshRules(dp=(), fsdp=(), tp=None, ep=None)
CFG = tfm.TransformerConfig(
    name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
    d_ff=64, vocab=128, q_chunk=16, loss_chunks=2, remat_policy="dots")


def _setup():
    params = tfm.init(jax.random.PRNGKey(0), CFG)
    return params, adamw_init(params)


def test_loss_decreases():
    params, opt = _setup()
    step = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdamWConfig(lr=3e-3), warmup=2, total_steps=50))
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, data.lm_batch(0, i, 4, 32, 128))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_accumulation_matches_full_batch():
    """accum_steps=4 must equal the full-batch gradient step (same math)."""
    params, opt = _setup()
    batch = data.lm_batch(0, 0, 8, 32, 128)
    s1 = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdamWConfig(lr=1e-3), accum_steps=1))
    s4 = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdamWConfig(lr=1e-3), accum_steps=4))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-2)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_hierarchical_remat_same_loss():
    """Blocked (native (nb, bs, ...) layout) == flat layer stacking."""
    cfg_b = tfm.TransformerConfig(
        name="tiny-blocks", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
        d_head=8, d_ff=64, vocab=128, q_chunk=16, loss_chunks=2,
        remat_policy="nothing", remat_block=2)
    cfg_plain = tfm.TransformerConfig(**{**cfg_b.__dict__, "remat_block": 0,
                                         "name": "tiny-plain"})
    params_b = tfm.init(jax.random.PRNGKey(0), cfg_b)    # (2, 2, ...) layers
    params_p = tfm.init(jax.random.PRNGKey(0), cfg_plain)  # (4, ...) layers
    batch = data.lm_batch(0, 0, 4, 32, 128)
    l_b, g_b = jax.value_and_grad(
        lambda p: tfm.train_loss(p, batch, cfg_b, RULES))(params_b)
    l_p, g_p = jax.value_and_grad(
        lambda p: tfm.train_loss(p, batch, cfg_plain, RULES))(params_p)
    np.testing.assert_allclose(float(l_b), float(l_p), rtol=1e-5)
    flat_b = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                          g_b["layers"])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-3,
        atol=1e-5), flat_b, g_p["layers"])


def test_checkpoint_restart_exact():
    """Fault tolerance: kill-and-restore reproduces the exact trajectory
    (stateless data pipeline + exact state roundtrip)."""
    params, opt = _setup()
    step = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdamWConfig(lr=1e-3), warmup=2, total_steps=50))
    with tempfile.TemporaryDirectory() as ckdir:
        for i in range(3):
            params, opt, _ = step(params, opt, data.lm_batch(7, i, 4, 32, 128))
        checkpoint.save(ckdir, 3, {"params": params, "opt": opt})
        # continue original
        p_a, o_a = params, opt
        for i in range(3, 6):
            p_a, o_a, m_a = step(p_a, o_a, data.lm_batch(7, i, 4, 32, 128))
        # simulated failure: restore and replay
        restored, step_no, _ = checkpoint.restore(
            ckdir, {"params": params, "opt": opt})
        p_b, o_b = restored["params"], restored["opt"]
        assert step_no == 3
        for i in range(3, 6):
            p_b, o_b, m_b = step(p_b, o_b, data.lm_batch(7, i, 4, 32, 128))
        assert float(m_a["loss"]) == float(m_b["loss"])
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), p_a, p_b)


def test_checkpoint_latest_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        assert checkpoint.latest_step(d) is None
        checkpoint.save(d, 1, {"x": jnp.ones(3)})
        checkpoint.save(d, 2, {"x": jnp.ones(3) * 2})
        assert checkpoint.latest_step(d) == 2
        tree, s, _ = checkpoint.restore(d, {"x": jnp.zeros(3)})
        assert s == 2 and tree["x"][0] == 2
        tree, s, _ = checkpoint.restore(d, {"x": jnp.zeros(3)}, step=1)
        assert s == 1 and tree["x"][0] == 1


def test_data_determinism():
    b1 = data.lm_batch(0, 5, 4, 16, 100)
    b2 = data.lm_batch(0, 5, 4, 16, 100)
    b3 = data.lm_batch(0, 6, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_lr_schedule():
    assert float(cosine_warmup_lr(jnp.asarray(0), 1.0, 10, 100)) == 0.0
    assert abs(float(cosine_warmup_lr(jnp.asarray(10), 1.0, 10, 100))
               - 1.0) < 1e-6
    assert float(cosine_warmup_lr(jnp.asarray(100), 1.0, 10, 100)) < 0.11


def test_adafactor_decreases_loss():
    from repro.train.optimizer import (AdafactorConfig, adafactor_init)
    params = tfm.init(jax.random.PRNGKey(0), CFG)
    opt = adafactor_init(params)
    step = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdafactorConfig(lr=3e-2), warmup=2, total_steps=50))
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, data.lm_batch(3, i, 4, 32, 128))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
    # factored state is O(m + n), not O(mn): check a matrix leaf
    vr = opt.vr["layers"]["wq"]
    wq = params["layers"]["wq"]
    assert vr.shape == wq.shape[:-1]


def test_bf16_accumulation_close_to_fp32():
    params = tfm.init(jax.random.PRNGKey(0), CFG)
    opt = adamw_init(params)
    batch = data.lm_batch(0, 0, 8, 32, 128)
    s32 = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdamWConfig(lr=1e-3), accum_steps=4))
    import jax.numpy as jnp2
    s16 = jax.jit(make_train_step(
        lambda p, b: tfm.train_loss(p, b, CFG, RULES),
        AdamWConfig(lr=1e-3), accum_steps=4, accum_dtype=jnp2.bfloat16))
    _, _, m32 = s32(params, opt, batch)
    _, _, m16 = s16(params, opt, batch)
    np.testing.assert_allclose(float(m32["grad_norm"]),
                               float(m16["grad_norm"]), rtol=5e-2)
